"""Hypothesis fuzz sweep over the fused refine kernel's exactness
contract, across every (Q, K, M, L, dtype, kernel structure) the
dispatcher can take (skips cleanly when hypothesis is absent).

Two layers, two contracts (see kernels/refine.py's module docstring):

* kernel level — every structure (Mosaic dma_depth=1, the dma_depth>=2
  DMA-ring, and Triton at several block_q) returns the SAME entry
  buffer bit for bit as the materializing oracle `ref.refine_topk_ref`,
  with distances within a few ULP (XLA may re-associate the oracle's
  batched einsum; the kernels accumulate in a fixed order — empirical
  worst over 10^3 sweeps is 3 ULP, gated at 8 for slack: a real defect
  diverges by orders of magnitude, not units-in-the-last-place);
* run_search level — the full search is bitwise identical between
  backend='ref' and backend='pallas' (winners' distances are recomputed
  in direct form from identical entry buffers), and id-identical to the
  brute-force oracle.

Degenerate shapes ride inside the strategies: all-pruned rounds
(alive_mode='none'), a single leaf (NL=1), Q=1, and k larger than the
round's candidate count (k=11 vs K*M as small as 4).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp                                    # noqa: E402
from hypothesis import HealthCheck, given, settings        # noqa: E402
from hypothesis import strategies as st                    # noqa: E402

from repro.core import build_index, run_search, search_bruteforce  # noqa: E402
from repro.data.synthetic import random_walk               # noqa: E402
from repro.kernels import ops, ref                         # noqa: E402

# (lowering, dma_depth, block_q): all three kernel structures, the ring
# at two depths and Triton at three block widths — every combination the
# autotune sweep can propose
STRUCTURES = (("mosaic", 1, 1), ("mosaic", 2, 1), ("mosaic", 4, 1),
              ("triton", 1, 1), ("triton", 1, 2), ("triton", 1, 4))

# sampled (not drawn free-form) so jit caches are shared across examples
# and the 50+ cases stay fast in interpret mode.  Each example draws ONE
# structure: every distinct (shape, structure) combination is a fresh
# XLA compile whose executable holds ~65 memory mappings for the life of
# the process, and an unbounded cross-product walks the pytest process
# into the vm.max_map_count ceiling (mmap ENOMEM) long before it runs
# out of RAM.
S_Q = st.sampled_from((1, 2, 5))
S_K = st.sampled_from((1, 3, 4))
S_M = st.sampled_from((4, 8))
S_L = st.sampled_from((32, 64))
S_NL = st.sampled_from((1, 3, 9))
S_K_NN = st.sampled_from((1, 3, 11))
S_DTYPE = st.sampled_from(("float32", "bfloat16"))
S_ALIVE = st.sampled_from(("random", "none", "all"))
S_STRUCTURE = st.sampled_from(STRUCTURES)


def _ulp_diff(a, b) -> np.ndarray:
    """ULP distance between non-negative f32 arrays (distances)."""
    ai = np.ascontiguousarray(np.asarray(a, np.float32)).view(np.int32)
    bi = np.ascontiguousarray(np.asarray(b, np.float32)).view(np.int32)
    return np.abs(ai.astype(np.int64) - bi.astype(np.int64))


def _case(Q, K, M, NL, L, k, dtype, alive_mode, seed):
    rng = np.random.default_rng(seed)
    stored = jnp.asarray(rng.standard_normal((NL * M, L)),
                         getattr(jnp, dtype))
    series_f32 = stored.astype(jnp.float32)
    sqn = jnp.sum(series_f32 * series_f32, -1)
    q = jnp.asarray(rng.standard_normal((Q, L)), jnp.float32)
    qsq = jnp.sum(q * q, -1)
    ids = jnp.asarray(rng.integers(0, NL, (Q, K)), jnp.int32)
    if alive_mode == "none":
        alive = jnp.zeros((Q, K), bool)
    elif alive_mode == "all":
        alive = jnp.ones((Q, K), bool)
    else:
        alive = jnp.asarray(rng.integers(0, 2, (Q, K)).astype(bool))
    bsf_d = jnp.full((Q, k), 1e30, jnp.float32)
    bsf_e = jnp.zeros((Q, k), jnp.int32)
    return q, qsq, stored, series_f32, sqn, ids, alive, bsf_d, bsf_e


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(Q=S_Q, K=S_K, M=S_M, NL=S_NL, L=S_L, k=S_K_NN, dtype=S_DTYPE,
       alive_mode=S_ALIVE, structure=S_STRUCTURE,
       seed=st.integers(0, 2**16 - 1))
def test_every_structure_matches_the_oracle(Q, K, M, NL, L, k, dtype,
                                            alive_mode, structure, seed):
    q, qsq, stored, series_f32, sqn, ids, alive, bsf_d, bsf_e = _case(
        Q, K, M, NL, L, k, dtype, alive_mode, seed)
    # the oracle sees the same stored-dtype values the kernels gather
    dr, er = ref.refine_topk_ref(q, qsq, stored, sqn, ids, alive,
                                 bsf_d, bsf_e, leaf_capacity=M, k=k)
    dr, er = np.asarray(dr), np.asarray(er)
    lowering, dd, bq = structure
    dk, ek = ops.refine_topk(q, qsq, stored, sqn, ids, alive,
                             bsf_d, bsf_e, leaf_capacity=M, k=k,
                             interpret=True, lowering=lowering,
                             dma_depth=dd, block_q=bq)
    np.testing.assert_array_equal(np.asarray(ek), er, err_msg=str(
        ("entry buffer mismatch", lowering, dd, bq,
         Q, K, M, NL, L, k, dtype, alive_mode, seed)))
    ulp = _ulp_diff(dk, dr)
    assert ulp.max(initial=0) <= 8, (
        "distance beyond 8 ULP of the oracle", lowering, dd, bq,
        int(ulp.max()), Q, K, M, NL, L, k, dtype, alive_mode, seed)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(Q=st.sampled_from((1, 5)), k=S_K_NN, alive_mode=S_ALIVE,
       seed=st.integers(0, 2**16 - 1))
def test_structures_agree_on_the_carried_buffer(Q, k, alive_mode, seed):
    """Two chained rounds (the second folds into a non-trivial carry):
    every structure must thread the SAME buffer through both.  Shape
    axes beyond (Q, k) are pinned — this test DOES loop all six
    structures per example, so its jit-key budget is kept small."""
    K, NL, M, L = 3, 6, 8, 32
    q, qsq, stored, _, sqn, ids, alive, bsf_d, bsf_e = _case(
        Q, K, M, NL, L, k, "float32", alive_mode, seed)
    ids2 = jnp.asarray(
        np.random.default_rng(seed + 1).integers(0, NL, (Q, K)), jnp.int32)
    outs = []
    for lowering, dd, bq in STRUCTURES:
        d1, e1 = ops.refine_topk(q, qsq, stored, sqn, ids, alive,
                                 bsf_d, bsf_e, leaf_capacity=M, k=k,
                                 interpret=True, lowering=lowering,
                                 dma_depth=dd, block_q=bq)
        d2, e2 = ops.refine_topk(q, qsq, stored, sqn, ids2,
                                 jnp.ones_like(alive), d1, e1,
                                 leaf_capacity=M, k=k, interpret=True,
                                 lowering=lowering, dma_depth=dd,
                                 block_q=bq)
        outs.append((lowering, dd, bq, np.asarray(d2), np.asarray(e2)))
    _, _, _, d0, e0 = outs[0]
    for lowering, dd, bq, d, e in outs[1:]:
        np.testing.assert_array_equal(e, e0, err_msg=str(
            ("chained entries diverged", lowering, dd, bq, seed)))
        assert _ulp_diff(d, d0).max(initial=0) <= 8, (
            lowering, dd, bq, seed)


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(shape=st.sampled_from(((64, 32, 8), (130, 64, 16), (257, 64, 32))),
       k=st.sampled_from((1, 5, 10)),
       round_leaves=st.sampled_from((2, 8)),
       seed=st.integers(0, 2**12 - 1))
def test_run_search_backends_bitwise_and_oracle_ids(shape, k, round_leaves,
                                                    seed):
    n, L, cap = shape
    walks = random_walk(n, L, seed=seed % 97)
    idx = build_index(jnp.asarray(walks), leaf_capacity=cap)
    rng = np.random.default_rng(seed)
    base = walks[rng.integers(0, n, 3)]
    q = jnp.asarray(base + 0.05 * rng.standard_normal(base.shape),
                    jnp.float32)
    dr, ir = run_search(idx, q, k=k, round_leaves=round_leaves,
                        backend="ref")
    dp, ip = run_search(idx, q, k=k, round_leaves=round_leaves,
                        backend="pallas")
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    assert np.asarray(dp).tobytes() == np.asarray(dr).tobytes(), (
        "run_search distances not bitwise across backends",
        shape, k, round_leaves, seed)
    db, ib = search_bruteforce(jnp.asarray(walks), q, k=k)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ib))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(db),
                               rtol=1e-4, atol=1e-4)
