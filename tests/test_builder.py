"""IndexBuilder: the Refresh-driven build pipeline (paper §IV-V).

The load-bearing property is schedule-independence: a multi-worker build
under crash/delay injectors, a streaming chunked feed, and the sequential
single-shot `FreshIndex.build` must all produce BIT-IDENTICAL FlatIndex
arrays — and the fused one-program `build_index` must agree too.
Compaction is the same machinery: `merge_sorted_delta` consumes the
stored core arrays as-is, so repeated compacts are drift-free even with
half-precision storage (compact∘compact == compact).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import FreshIndex, IndexConfig
from repro.core import (IndexBuilder, build_index, merge_sorted_delta,
                        search_bruteforce)
from repro.core.refresh import Injectors
from repro.data.synthetic import random_walk


def _assert_bit_identical(a, b, context=""):
    for f in a._fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.dtype == y.dtype, (context, f, x.dtype, y.dtype)
        # ml_dtypes halves compare exactly via their bit patterns
        if x.dtype.itemsize == 2 and x.dtype.kind != "u":
            x, y = x.view(np.uint16), y.view(np.uint16)
        np.testing.assert_array_equal(x, y, err_msg=f"{context}: {f}")


@pytest.fixture(scope="module")
def small(walks):
    return walks[:1024]


@pytest.fixture(scope="module")
def reference(small):
    return FreshIndex.build(small, IndexConfig(leaf_capacity=32))


# --------------------------------------------------------------------- #
# the host-side key machinery == the device key (bit-identity foundation)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("bits,segments", [(8, 16), (4, 8), (3, 5)])
def test_interleaved_key_np_matches_jnp(bits, segments):
    """The numpy key mirror the builder's sort/merge phases use must be
    bit-identical to the device key, its stable lexsort must equal
    jnp.lexsort's permutation, and the byte-packed scalar key (the merge
    path's binary-search key) must order exactly like the lane tuple.
    (Lives here, not in test_isax.py: that module skips without
    hypothesis, and these properties must run in CI.)"""
    from repro.core import isax
    rng = np.random.default_rng(3)
    words = rng.integers(0, 1 << bits, size=(257, segments), dtype=np.uint8)
    kj = np.asarray(isax.interleaved_key(jnp.asarray(words), bits))
    kn = isax.interleaved_key_np(words, bits)
    np.testing.assert_array_equal(kj, kn)
    lanes = [jnp.asarray(kj[:, i]) for i in range(kj.shape[1])]
    perm_j = np.asarray(jnp.lexsort(tuple(reversed(lanes))))
    np.testing.assert_array_equal(perm_j, isax.lexsort_keys(kn))
    packed = isax.pack_keys_bytes(kn)
    np.testing.assert_array_equal(np.argsort(packed, kind="stable"),
                                  isax.lexsort_keys(kn))


# --------------------------------------------------------------------- #
# the single-shot paths agree: builder pipeline == fused device program
# --------------------------------------------------------------------- #
def test_pipeline_matches_fused_build(small, reference):
    fused = build_index(jnp.asarray(small), leaf_capacity=32)
    _assert_bit_identical(reference.index, fused, "pipeline vs fused")


# --------------------------------------------------------------------- #
# multi-worker builds under injectors: bit-identical, still terminate
# --------------------------------------------------------------------- #
def test_multiworker_crash_build_bit_identical(small, reference):
    """4 workers, 3 of them crash permanently after one payload each —
    the surviving worker (plus the calling thread, if need be) helps
    every phase to completion and the result is bit-identical."""
    b = IndexBuilder(IndexConfig(leaf_capacity=32), workers=4,
                     part_rows=128,
                     injectors=Injectors.crashing({1, 2, 3}, after=1))
    ix = b.feed(small).finalize()
    _assert_bit_identical(ix.index, reference.index, "crash build")
    rep = b.report()
    assert rep["workers"] == 4
    crashed = sum(p["crashed_workers"] for p in rep["phases"].values())
    helped = sum(p["helped_parts"] for p in rep["phases"].values())
    assert crashed >= 3, rep
    assert helped > 0, rep
    apps = sum(p["applications"] for p in rep["phases"].values())
    parts = sum(p["parts"] for p in rep["phases"].values())
    assert apps >= parts  # helping may duplicate, never skip


def test_all_workers_crash_still_completes(small, reference):
    """Even with EVERY worker crashed at its first payload, finalize()
    terminates (traverse_complete: the caller helps) — the strongest
    form of the paper's progress property we can state."""
    b = IndexBuilder(IndexConfig(leaf_capacity=32), workers=4,
                     part_rows=256,
                     injectors=Injectors.crashing({0, 1, 2, 3}, after=0))
    ix = b.feed(small).finalize()
    _assert_bit_identical(ix.index, reference.index, "all-crash build")


def test_multiworker_delay_build_bit_identical(small, reference):
    b = IndexBuilder(IndexConfig(leaf_capacity=32), workers=4,
                     part_rows=128,
                     injectors=Injectors.delaying(0.002, worker_ids={0},
                                                  every=2))
    ix = b.feed(small).finalize()
    _assert_bit_identical(ix.index, reference.index, "delay build")


# --------------------------------------------------------------------- #
# streaming feed: N chunks == one-shot, and the result answers exactly
# --------------------------------------------------------------------- #
def test_feed_chunks_equals_oneshot(small, reference, queries):
    b = FreshIndex.builder(IndexConfig(leaf_capacity=32))
    for lo in range(0, small.shape[0], 192):       # ragged, non-part-sized
        b.feed(small[lo:lo + 192])
    ix = b.finalize()
    _assert_bit_identical(ix.index, reference.index, "chunked feed")
    q = jnp.asarray(queries[:8])
    for k in (1, 5, 10):
        d, i = ix.search(q, k=k)
        db, ib = search_bruteforce(jnp.asarray(small), q, k=k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                                   rtol=1e-5, atol=1e-5)


def test_feed_is_eager_for_complete_blocks(small):
    """Streaming ingest: summarize/key/sort run at feed() time for every
    complete part_rows block, not all at finalize()."""
    b = IndexBuilder(IndexConfig(leaf_capacity=32), part_rows=256)
    b.feed(small[:600])
    rep = b.report()
    assert rep["phases"]["summarize"]["parts"] == 2      # 600 // 256
    assert rep["phases"]["sort"]["parts"] == 2
    assert rep["phases"]["merge"]["parts"] == 0          # finalize-only
    b.feed(small[600:]).finalize()
    assert b.report()["phases"]["merge"]["parts"] > 0


def test_feed_copies_reused_caller_buffer(small, reference):
    """Read-into-buffer streaming: the caller refills ONE buffer between
    feeds.  The builder must not alias it (tail rows outlive the call)."""
    b = IndexBuilder(IndexConfig(leaf_capacity=32), part_rows=256)
    buf = np.empty((100, 256), np.float32)
    for lo in range(0, small.shape[0], 100):
        chunk = small[lo:lo + 100]
        buf[:chunk.shape[0]] = chunk
        b.feed(buf[:chunk.shape[0]])
        buf[:] = np.nan                          # caller reuses the buffer
    ix = b.finalize()
    _assert_bit_identical(ix.index, reference.index, "reused feed buffer")


def test_add_copies_reused_caller_buffer(walks, queries):
    """FreshIndex.add must own its delta rows for the same reason."""
    base = walks[:512]
    extra = random_walk(32, 256, seed=36)
    ix = FreshIndex.build(base, IndexConfig(leaf_capacity=32))
    buf = np.array(extra[:16])
    ix.add(buf)
    buf[:] = np.nan
    ix.add(extra[16:])                           # invalidates delta_cat
    ix.compact()
    fresh = FreshIndex.build(np.concatenate([base, extra]),
                             IndexConfig(leaf_capacity=32))
    _assert_bit_identical(ix.index, fresh.index, "reused add buffer")


def test_builder_validation():
    b = IndexBuilder(IndexConfig(leaf_capacity=32))
    with pytest.raises(ValueError, match="no data fed"):
        b.finalize()
    with pytest.raises(ValueError, match="not divisible"):
        b.feed(np.zeros((4, 250), np.float32))
    b.feed(np.zeros((4, 256), np.float32))
    with pytest.raises(ValueError, match="series length"):
        b.feed(np.zeros((4, 128), np.float32))
    b.finalize()
    with pytest.raises(RuntimeError, match="finalize"):
        b.feed(np.zeros((4, 256), np.float32))
    with pytest.raises(RuntimeError, match="finalize"):
        b.finalize()
    with pytest.raises(ValueError, match="part_rows"):
        IndexBuilder(IndexConfig(), part_rows=0)


# --------------------------------------------------------------------- #
# incremental compaction: stored arrays consumed as-is
# --------------------------------------------------------------------- #
def _rows_by_id(flat):
    """Index arrays keyed by original series id (bit-comparable dict)."""
    perm = np.asarray(flat.perm)
    v = perm >= 0
    order = np.argsort(perm[v])
    series = np.asarray(flat.series)[v][order]
    if series.dtype.itemsize == 2:
        series = series.view(np.uint16)
    return (series, np.asarray(flat.paa)[v][order],
            np.asarray(flat.words)[v][order],
            np.asarray(flat.sq_norms)[v][order])


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
def test_compact_preserves_stored_core_bits(walks, dtype):
    """The documented low-precision drift is gone: compact() keeps every
    already-stored row's series/paa/words/sq_norms bit-identical — no
    re-normalization, no re-rounding through float32."""
    base = walks[:512]
    cfg = IndexConfig(leaf_capacity=32, dtype=dtype)
    ix = FreshIndex.build(base, cfg)
    before = _rows_by_id(ix.index)
    ix.add(random_walk(40, 256, seed=31)).compact()
    after = _rows_by_id(ix.index)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a[:512])


@pytest.mark.parametrize("dtype", ["bfloat16", "float32"])
def test_compact_compact_equals_compact(walks, dtype):
    """compact∘compact == compact: splitting the same adds over two
    compacts is bit-identical to one compact (each row rounds through
    the storage dtype exactly once, at ITS first compact), and a compact
    with an empty delta is a no-op."""
    base = walks[:512]
    cfg = IndexConfig(leaf_capacity=32, dtype=dtype)
    b1 = random_walk(40, 256, seed=32)
    b2 = random_walk(56, 256, seed=33)

    two = FreshIndex.build(base, cfg)
    two.add(b1).compact()
    two.add(b2).compact()

    one = FreshIndex.build(base, cfg)
    one.add(b1).add(b2).compact()

    _assert_bit_identical(two.index, one.index, f"{dtype} split compacts")
    before = two.index
    assert two.compact() is two                  # empty delta: no-op
    assert two.index is before


def test_compact_matches_fresh_build_f32(walks, queries):
    """float32 storage: the incremental merge is bit-identical to a fresh
    build over the concatenation (stronger than the facade-level test in
    test_api.py — every array, not just perm/search results)."""
    base, extra = walks[:512], random_walk(64, 256, seed=34)
    ix = FreshIndex.build(base, IndexConfig(leaf_capacity=32))
    ix.add(extra).compact()
    fresh = FreshIndex.build(np.concatenate([base, extra]),
                             IndexConfig(leaf_capacity=32))
    _assert_bit_identical(ix.index, fresh.index, "merge vs fresh")


def test_empty_build_then_add_compact_bootstrap(walks, queries):
    """FreshIndex.build over a (0, L) array is legal (the bootstrap
    pattern): the empty core merges its first delta on compact() and
    answers bit-identically to a direct build."""
    data = walks[:256]
    ix = FreshIndex.build(np.empty((0, 256), np.float32),
                          IndexConfig(leaf_capacity=32))
    assert ix.n_series == 0
    ix.add(data).compact()
    direct = FreshIndex.build(data, IndexConfig(leaf_capacity=32))
    _assert_bit_identical(ix.index, direct.index, "bootstrap build")
    q = jnp.asarray(queries[:4])
    d, i = ix.search(q, k=5)
    db, ib = search_bruteforce(jnp.asarray(data), q, k=5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))


def test_merge_sorted_delta_direct_and_empty(walks):
    cfg = IndexConfig(leaf_capacity=32)
    ix = FreshIndex.build(walks[:256], cfg)
    assert merge_sorted_delta(ix.index, np.zeros((0, 256), np.float32),
                              cfg) is ix.index
    with pytest.raises(ValueError, match="delta must be"):
        merge_sorted_delta(ix.index, np.zeros((4,), np.float32), cfg)


def test_reconstruct_data_is_gone():
    """compact() no longer reconstructs the dataset into original id
    order for a from-scratch rebuild (the merge consumes the stored
    leaf-ordered arrays directly)."""
    assert not hasattr(FreshIndex, "_reconstruct_data")


# --------------------------------------------------------------------- #
# serving: auto-compaction reuses the merge primitive
# --------------------------------------------------------------------- #
def test_engine_auto_compact(walks, queries):
    base = walks[:512]
    ix = FreshIndex.build(base, IndexConfig(leaf_capacity=32))
    extra = random_walk(48, 256, seed=35)
    q = jnp.asarray(queries[:6])
    with ix.engine(max_batch=8, auto_compact_rows=40) as eng:
        eng.add(extra[:24])                      # below threshold: delta
        assert ix.n_pending == 24
        eng.add(extra[24:])                      # 48 >= 40: auto-compact
        assert ix.n_pending == 0
        fut = eng.submit(queries[:6], k=5)
        eng.flush()
        d, i = fut.result(timeout=60)
        st = eng.stats()
    assert st["compactions"] == 1
    both = jnp.asarray(np.concatenate([base, extra]))
    db, ib = search_bruteforce(both, q, k=5)
    np.testing.assert_array_equal(i, np.asarray(ib))
