"""The full (arch x shape) matrix at the SPEC level (fast, no compile):
every cell must produce consistent abstract inputs, plans, and sharding
trees on a debug mesh — the cheap half of what the dry-run proves."""

import subprocess
import sys
import os
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, SHAPES_BY_NAME, get_config,
                           supports_shape)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_and_counts(arch):
    """Abstract init works for the FULL config; analytic param counts
    match eval_shape within vocab-padding slack."""
    from repro.models import LM
    from repro.launch.specs import abstract_params
    from repro.models.transformer import pad_vocab
    cfg = get_config(arch)
    model = LM(cfg)
    p_abs, p_axes = abstract_params(model)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(p_abs))
    pred = cfg.param_counts()["total"]
    pad_extra = (pad_vocab(cfg.vocab) - cfg.vocab) * cfg.d_model \
        * (1 if cfg.tie_embeddings else 2)
    # padded dummy experts (qwen2) add up to 4/60 of expert params
    assert abs(actual - pad_extra - pred) / pred < 0.10, \
        (arch, actual / 1e9, pred / 1e9)
    # every leaf has an axes tuple of matching rank
    for v, a in zip(jax.tree.leaves(p_abs), jax.tree.leaves(
            p_axes, is_leaf=lambda x: isinstance(x, tuple))):
        assert len(a) == v.ndim


def test_all_cells_specs_on_debug_mesh():
    """input_specs + plan + sharding trees for all 40 cells (8 fake
    devices, subprocess)."""
    body = """
    import jax, numpy as np
    from repro.configs import (ARCH_IDS, SHAPES_BY_NAME, get_config,
                               supports_shape)
    from repro.launch.specs import (abstract_params, batch_shardings,
                                    input_specs, param_shardings)
    from repro.models import LM
    from repro.runtime.sharding import make_plan
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = LM(cfg)
        p_abs, p_axes = abstract_params(model)
        for sname, shape in SHAPES_BY_NAME.items():
            if not supports_shape(cfg, shape):
                continue
            plan = make_plan(cfg, mesh, decode=shape.kind == "decode",
                             prefill=shape.kind == "prefill")
            sh = param_shardings(plan, p_axes)
            specs = input_specs(cfg, shape)
            bsh = batch_shardings(plan, specs)
            assert set(bsh) == set(specs), (arch, sname)
            # every param sharding divides its dims
            for v, s in zip(jax.tree.leaves(p_abs), jax.tree.leaves(
                    sh, is_leaf=lambda x: hasattr(x, "spec"))):
                for dim, part in zip(v.shape, s.spec):
                    if part is None:
                        continue
                    size = np.prod([mesh.shape[a] for a in
                                    ((part,) if isinstance(part, str)
                                     else part)])
                    assert dim % size == 0, (arch, v.shape, s.spec)
            n += 1
    print("cells validated:", n)
    assert n == 33
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "cells validated: 33" in r.stdout
