"""Shared fixtures.  Deliberately does NOT set XLA_FLAGS: smoke tests must
see 1 CPU device; multi-device tests spawn subprocesses with their own
flags (see tests/test_sharded.py)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def walks():
    """A small random-walk collection shared across tests."""
    from repro.data.synthetic import random_walk
    return random_walk(2048, 256, seed=7)


@pytest.fixture(scope="session")
def queries(walks):
    from repro.data.synthetic import query_workload
    return query_workload(walks, 24, noise_sigma=0.05, seed=11)
