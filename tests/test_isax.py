"""Unit tests for the iSAX math (core/isax.py): the numeric foundation.

The pruning property (MINDIST <= ED) is THE soundness invariant of the
whole index — if it ever breaks, exact search silently returns wrong
answers.  It gets both fixed-seed and hypothesis coverage.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import isax


def test_ndtri_matches_known_quantiles():
    # N(0,1) quantiles: Phi^-1(0.5)=0, Phi^-1(0.975)~1.959964
    assert abs(isax.ndtri(np.array([0.5]))[0]) < 1e-9
    assert abs(isax.ndtri(np.array([0.975]))[0] - 1.959964) < 1e-5
    assert abs(isax.ndtri(np.array([0.025]))[0] + 1.959964) < 1e-5


def test_breakpoints_monotone_and_symmetric():
    for bits in (1, 2, 4, 8):
        bp = isax.breakpoints(bits)
        assert len(bp) == (1 << bits) - 1
        assert np.all(np.diff(bp) > 0)
        np.testing.assert_allclose(bp, -bp[::-1], atol=1e-12)


def test_paa_mean_preserving():
    x = jnp.arange(32.0).reshape(2, 16)
    p = isax.paa(x, 4)
    assert p.shape == (2, 4)
    np.testing.assert_allclose(np.asarray(p[0]),
                               [1.5, 5.5, 9.5, 13.5], atol=1e-6)


def test_sax_word_bounds_and_regions():
    p = jnp.asarray([[-10.0, 0.0, 10.0, 0.3]])
    w = isax.sax_word(p, 8)
    assert int(w[0, 0]) == 0
    assert int(w[0, 2]) == 255
    lo, hi = isax.symbol_region(w, 8, 8)
    # every PAA value must lie in its full-cardinality region
    assert np.all(np.asarray(lo[0]) <= np.asarray(p[0]))
    assert np.all(np.asarray(p[0]) <= np.asarray(hi[0]))


def test_root_bucket_packs_msbs():
    w = jnp.zeros((1, 4), jnp.uint8).at[0, 0].set(128)  # MSB of seg 0 only
    b = isax.root_bucket(w, 8)
    assert int(b[0]) == 8  # 1000_2


def test_interleaved_key_orders_like_msb_planes():
    # two words differing only in MSB of segment 0 must order by it
    a = jnp.asarray([[0x80, 0, 0, 0]], jnp.uint8)
    b = jnp.asarray([[0x7F, 0xFF, 0xFF, 0xFF]], jnp.uint8)
    ka = np.asarray(isax.interleaved_key(a, 8))[0]
    kb = np.asarray(isax.interleaved_key(b, 8))[0]
    assert tuple(ka) > tuple(kb)


def _pruning_gap(series, query):
    """returns (mindist, euclid) for znormalized inputs."""
    x = isax.znormalize(jnp.asarray(series, jnp.float32))
    q = isax.znormalize(jnp.asarray(query, jnp.float32))
    L = x.shape[-1]
    p, w = isax.summarize(x)
    qp = isax.paa(q)
    lb = isax.mindist_isax_sq(qp, w, series_len=L)
    ed = isax.euclidean_sq(q, x)
    return np.asarray(lb), np.asarray(ed)


def test_pruning_property_fixed(walks, queries):
    lb, ed = _pruning_gap(walks[:256], queries[:1])
    assert np.all(lb <= ed + 1e-3 * np.maximum(ed, 1.0))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_pruning_property_hypothesis(seed):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal((8, 256)), axis=1)
    q = np.cumsum(rng.standard_normal((1, 256)), axis=1)
    lb, ed = _pruning_gap(x, q)
    assert np.all(lb <= ed + 1e-3 * np.maximum(ed, 1.0)), \
        f"pruning property violated: lb={lb}, ed={ed}"


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([4, 8, 16]))
def test_pruning_property_param_sweep(seed, bits, segments):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal((4, 64)), axis=1)
    q = np.cumsum(rng.standard_normal((1, 64)), axis=1)
    xz = isax.znormalize(jnp.asarray(x, jnp.float32))
    qz = isax.znormalize(jnp.asarray(q, jnp.float32))
    p, w = isax.summarize(xz, segments, bits)
    qp = isax.paa(qz, segments)
    lb = np.asarray(isax.mindist_isax_sq(qp, w, bits, bits, 64))
    ed = np.asarray(isax.euclidean_sq(qz, xz))
    assert np.all(lb <= ed + 1e-3 * np.maximum(ed, 1.0))


def test_mindist_at_reduced_depth_is_looser():
    """Internal-node bounds (fewer prefix bits) must be <= leaf bounds."""
    rng = np.random.default_rng(3)
    x = isax.znormalize(jnp.asarray(
        np.cumsum(rng.standard_normal((16, 256)), 1), jnp.float32))
    q = isax.znormalize(jnp.asarray(
        np.cumsum(rng.standard_normal((1, 256)), 1), jnp.float32))
    _, w = isax.summarize(x)
    qp = isax.paa(q)
    prev = None
    for depth in (8, 4, 2, 1):
        lb = np.asarray(isax.mindist_isax_sq(qp, w, depth))
        if prev is not None:
            assert np.all(lb <= prev + 1e-5)
        prev = lb
