"""Device data plane: flat index build + exact search vs brute force."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_index, index_stats, run_search,
                        search_bruteforce)
from repro.core.index import leaf_regions
from repro.core import isax


@pytest.fixture(scope="module")
def built(walks):
    raw = jnp.asarray(walks)
    return raw, build_index(raw, leaf_capacity=64)


def test_index_shapes_and_stats(built, walks):
    raw, idx = built
    st = index_stats(idx)
    assert st["n_series"] == walks.shape[0]
    assert st["n_leaves"] * idx.leaf_capacity >= walks.shape[0]
    assert st["max_fill"] <= idx.leaf_capacity


def test_exact_search_matches_bruteforce(built, queries):
    raw, idx = built
    q = jnp.asarray(queries)
    d, i = run_search(idx, q)
    db, ib = search_bruteforce(raw, q)
    np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                               rtol=1e-4, atol=1e-4)
    # ids may differ only on exact distance ties
    mism = np.asarray(i) != np.asarray(ib)
    if mism.any():
        np.testing.assert_allclose(np.asarray(d)[mism],
                                   np.asarray(db)[mism], rtol=1e-5)


@pytest.mark.parametrize("bound", ["prefix", "symbox", "paabox"])
def test_every_leaf_bound_is_sound(walks, queries, bound):
    raw = jnp.asarray(walks[:512])
    idx = build_index(raw, leaf_capacity=32, bound=bound)
    q = jnp.asarray(queries[:8])
    d, i = run_search(idx, q)
    db, ib = search_bruteforce(raw, q)
    np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                               rtol=1e-4, atol=1e-4)


def test_leaf_regions_contain_members(walks):
    raw = jnp.asarray(walks[:512])
    idx = build_index(raw, leaf_capacity=32)
    # each member's PAA must lie inside its leaf's [lo, hi] region box is
    # only required for paabox; for prefix bounds the SYMBOL region applies
    M = idx.leaf_capacity
    words = np.asarray(idx.words).reshape(idx.n_leaves, M, -1)
    valid = np.asarray(idx.valid).reshape(idx.n_leaves, M)
    lo = np.asarray(idx.leaf_lo)
    hi = np.asarray(idx.leaf_hi)
    pad = np.asarray(isax.padded_breakpoints())
    sym_lo = pad[words]
    sym_hi = pad[words.astype(np.int64) + 1]
    for lf in range(idx.n_leaves):
        v = valid[lf]
        if not v.any():
            continue
        assert np.all(lo[lf][None, :] <= sym_lo[lf][v] + 1e-6)
        assert np.all(sym_hi[lf][v] <= hi[lf][None, :] + 1e-6)


def test_search_with_max_rounds_is_upper_bound(built, queries):
    """Capped refinement is approximate but never better than exact."""
    raw, idx = built
    q = jnp.asarray(queries[:8])
    d_exact, _ = run_search(idx, q)
    d_cap, _ = run_search(idx, q, max_rounds=1)
    assert np.all(np.asarray(d_cap) >= np.asarray(d_exact) - 1e-5)


def test_build_is_deterministic(walks):
    raw = jnp.asarray(walks[:256])
    a = build_index(raw, leaf_capacity=32)
    b = build_index(raw, leaf_capacity=32)
    np.testing.assert_array_equal(np.asarray(a.perm), np.asarray(b.perm))


def test_search_single_query_batch(built):
    raw, idx = built
    q = jnp.asarray(np.asarray(raw[3:4]))  # a collection member: dist 0
    d, i = run_search(idx, q)
    assert float(d[0]) < 1e-3
    assert int(i[0]) == 3


def test_deprecated_free_functions_still_work_but_warn(built, queries):
    """The migration-table shims: same answers as run_search, but loudly
    deprecated.  pytest.warns captures the warning, so the suite stays
    clean under -W error::DeprecationWarning (the smoke.sh leg)."""
    import jax
    from repro.core import search as deprecated_search
    from repro.core import make_sharded_search as deprecated_mss
    raw, idx = built
    q = jnp.asarray(queries[:4])
    with pytest.warns(DeprecationWarning, match="FreshIndex.search"):
        d, i = deprecated_search(idx, q)
    d0, i0 = run_search(idx, q)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d0))
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.warns(DeprecationWarning, match="FreshIndex.shard"):
        deprecated_mss(mesh)


def test_padded_index_reports_exact_distances():
    """Regression: perm contains -1 padding when n % leaf_capacity != 0;
    the winner-distance recompute must not misalign (argsort bug)."""
    from repro.data.synthetic import random_walk, query_workload
    w = random_walk(1000, 256, seed=13)          # 1000 % 64 != 0
    q = query_workload(w, 8, noise_sigma=0.05, seed=14)
    idx = build_index(jnp.asarray(w), leaf_capacity=64)
    d, i = run_search(idx, jnp.asarray(q))
    db, ib = search_bruteforce(jnp.asarray(w), jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
    np.testing.assert_allclose(np.asarray(d), np.asarray(db), atol=1e-5)
