"""Runtime FT machinery: elastic controller, straggler monitor, mesh
planning for surviving pods."""

import pytest

from repro.runtime.elastic import (ElasticController, MeshSpec,
                                   StragglerMonitor, plan_mesh_for)


def test_plan_mesh_for_pod_counts():
    m1 = plan_mesh_for(1)
    assert m1.shape == (16, 16) and m1.axes == ("data", "model")
    m2 = plan_mesh_for(2)
    assert m2.shape == (2, 16, 16) and m2.axes == ("pod", "data", "model")
    m3 = plan_mesh_for(3)
    assert m3.shape == (3, 16, 16)


def test_elastic_controller_detects_pod_loss():
    world = {"pods": 2}
    ctl = ElasticController(lambda: world["pods"])
    assert ctl.check() is None                 # steady state
    world["pods"] = 1                          # pod dies
    spec = ctl.check()
    assert spec is not None and spec.shape == (16, 16)
    assert ctl.check() is None                 # re-meshed, steady again
    world["pods"] = 2                          # pod rejoins
    spec = ctl.check()
    assert spec.shape == (2, 16, 16)


def test_elastic_controller_total_loss_raises():
    world = {"pods": 1}
    ctl = ElasticController(lambda: world["pods"])
    world["pods"] = 0
    with pytest.raises(RuntimeError):
        ctl.check()


def test_straggler_monitor_flags_slow_worker():
    mon = StragglerMonitor(n_workers=4, factor=1.5)
    for step in range(10):
        for w in range(4):
            mon.record(w, 1.0 if w != 2 else 2.5)
    assert mon.stragglers() == [2]
    assert abs(mon.median() - 1.0) < 0.2


def test_straggler_monitor_recovers():
    mon = StragglerMonitor(n_workers=2, factor=1.5, alpha=0.9)
    mon.record(0, 1.0)
    mon.record(1, 5.0)
    assert mon.stragglers() == [1]
    for _ in range(6):
        mon.record(1, 1.0)                     # back to normal
    assert mon.stragglers() == []
