"""AST concurrency lint: every rule fires on a violating fixture and
stays quiet on the disciplined twin; the repo itself gates clean."""

import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis.lint import (Violation, lint_file, lint_paths,
                                 load_allowlist)

REPO = Path(__file__).resolve().parent.parent


def _lint_src(tmp_path, code: str):
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(code))
    return lint_file(f)


def _rules(violations):
    return {v.rule for v in violations}


# --------------------------------------------------------- bare-acquire
def test_bare_acquire_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
            def bad(self):
                self._lock.acquire()
                self.x = 1
                self._lock.release()
        """)
    assert "bare-acquire" in _rules(vs)


def test_disciplined_acquire_ok(tmp_path):
    vs = _lint_src(tmp_path, """
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
            def with_block(self):
                with self._lock:
                    self.x = 1
            def try_finally(self):
                self._lock.acquire()
                try:
                    self.x = 1
                finally:
                    self._lock.release()
        """)
    assert "bare-acquire" not in _rules(vs)


def test_journal_acquire_is_not_a_lock(tmp_path):
    # WorkJournal.acquire() claims a work part; it is not a mutex
    vs = _lint_src(tmp_path, """
        def drain(journal):
            while True:
                pid = journal.acquire(0)
                if pid is None:
                    return
        """)
    assert "bare-acquire" not in _rules(vs)


# -------------------------------------------------- blocking-under-lock
def test_blocking_io_under_cv_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        import time
        class E:
            def bad(self):
                with self._cv:
                    open("/tmp/x", "w").write("a")
                    time.sleep(0.1)
                    self._journal.persist()
                    self.result.block_until_ready()
        """)
    msgs = [v for v in vs if v.rule == "blocking-under-lock"]
    assert len(msgs) >= 4


def test_delta_cat_under_cv_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        class E:
            def bad(self):
                with self._cv:
                    d = self._index.delta_cat
        """)
    assert "blocking-under-lock" in _rules(vs)


def test_blocking_outside_lock_ok(tmp_path):
    vs = _lint_src(tmp_path, """
        class E:
            def good(self):
                with self._cv:
                    n = len(self._pending)
                self._journal.persist()
                d = self._index.delta_cat
                return n, d
        """)
    assert "blocking-under-lock" not in _rules(vs)


# ---------------------------------------------------- snapshot-mutation
def test_snapshot_field_write_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        def bad(snap, rows):
            snap.delta = rows
            snap.n_total = snap.n_total + 1
        """)
    assert sum(v.rule == "snapshot-mutation" for v in vs) == 2


def test_object_setattr_flagged_outside_init(tmp_path):
    vs = _lint_src(tmp_path, """
        def smash(snap, rows):
            object.__setattr__(snap, "delta", rows)
        """)
    assert "snapshot-mutation" in _rules(vs)


def test_object_setattr_ok_in_post_init(tmp_path):
    vs = _lint_src(tmp_path, """
        import dataclasses
        @dataclasses.dataclass(frozen=True)
        class C:
            x: int
            def __post_init__(self):
                object.__setattr__(self, "x", max(0, self.x))
        """)
    assert "snapshot-mutation" not in _rules(vs)


# ------------------------------------------------------ jit-side-effect
def test_jit_side_effects_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        import time
        import jax
        LOG = []
        @jax.jit
        def step(x):
            print("tracing", x)
            t = time.time()
            LOG.append(t)
            return x * 2
        """)
    assert sum(v.rule == "jit-side-effect" for v in vs) >= 3


def test_fn_passed_to_jit_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        import jax
        def impl(x):
            print(x)
            return x
        fast = jax.jit(impl)
        """)
    assert "jit-side-effect" in _rules(vs)


def test_factory_inner_fn_flagged(tmp_path):
    vs = _lint_src(tmp_path, """
        import time
        def make_train_step(cfg):
            def step(params, batch):
                t0 = time.perf_counter()
                return params
            return step
        """)
    assert "jit-side-effect" in _rules(vs)


def test_jax_debug_print_ok(tmp_path):
    vs = _lint_src(tmp_path, """
        import jax
        @jax.jit
        def step(x):
            jax.debug.print("x={x}", x=x)
            return x * 2
        """)
    assert "jit-side-effect" not in _rules(vs)


# ---------------------------------------------------------- dead-module
def test_dead_module_detection(tmp_path):
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("from . import used\n")
    (pkg / "used.py").write_text("X = 1\n")
    (pkg / "unused.py").write_text("Y = 2\n")
    presets = pkg / "presets"
    presets.mkdir()
    (presets / "__init__.py").write_text("")
    (presets / "preset_a.py").write_text("Z = 3\n")
    (pkg / "registry.py").write_text(textwrap.dedent("""
        import importlib
        def load(name):
            return importlib.import_module(f"pkg.presets.{name}")
        if __name__ == "__main__":
            load("preset_a")
        """))
    vs = [v for v in lint_paths([tmp_path / "src"])
          if v.rule == "dead-module"]
    dead = {Path(v.path).parent.name + "/" + Path(v.path).stem
            for v in vs}
    assert "pkg/unused" in dead
    # preset_a is reachable via the dynamic-import f-string prefix,
    # registry via its __main__ guard, used via the package __init__
    assert not {"pkg/used", "presets/preset_a", "pkg/registry",
                "pkg/__init__"} & dead


def test_tests_dir_keeps_modules_alive(tmp_path):
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "only_tested.py").write_text("A = 1\n")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_x.py").write_text("from pkg.only_tested import A\n")
    vs = [v for v in lint_paths([tmp_path / "src"])
          if v.rule == "dead-module"]
    assert not any("only_tested" in v.path for v in vs)


# ---------------------------------------------------- allowlist + gate
def test_allowlist_parsing(tmp_path):
    allow = tmp_path / ".lint-allow"
    allow.write_text("# comment\n\nbare-acquire src/x.py  # why\n")
    assert load_allowlist(allow) == [("bare-acquire", "src/x.py")]


def test_repo_gates_clean():
    """`python -m repro.analysis.lint src/` exits 0 on the repo itself
    (with the committed allowlist) — the CI zero-violations gate."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "src/"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stdout + r.stderr


def test_gate_red_on_violating_fixture(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(snap, r):\n    snap.delta = r\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad)],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 1
    assert "snapshot-mutation" in r.stdout


def test_violation_str_format(tmp_path):
    v = Violation("bare-acquire", "a/b.py", 7, "msg")
    assert str(v) == "a/b.py:7: [bare-acquire] msg"
