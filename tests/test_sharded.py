"""Multi-device semantics: every sharded path must agree with its
single-device oracle.  Runs in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps seeing 1 device (jax pins the count at first init).
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_facade_knn_matches_oracle():
    """FreshIndex.shard(mesh): exact top-k on the sharded path, including
    a delta buffer and a compact() that re-pads leaves to the device
    count."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex
    from repro.core import search_bruteforce
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(2048, 256, seed=1)
    qs = jnp.asarray(query_workload(walks, 12, noise_sigma=0.05, seed=2))
    ix = FreshIndex.build(walks, leaf_capacity=64)
    mesh = jax.make_mesh((8,), ("data",))
    ix.shard(mesh)
    for k in (1, 10):
        d, i = ix.search(qs, k=k, sync_every=2)
        db, ib = search_bruteforce(jnp.asarray(walks), qs, k=k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                                   rtol=1e-5, atol=1e-5)
    extra = random_walk(100, 256, seed=3)         # 2148 series: 34 leaves,
    ix.add(extra); ix.compact()                   # pad_leaves -> 40
    both = jnp.asarray(np.concatenate([walks, extra]))
    d, i = ix.search(qs, k=10)
    db, ib = search_bruteforce(both, qs, k=10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
    print("sharded facade knn OK")
    """)


def test_sharded_pallas_backend_matches_oracle():
    """backend='pallas' (resolved from IndexConfig) on the sharded path:
    each device's refine closure runs the fused kernel; results must be
    identical to the ref backend and the brute-force oracle."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(1024, 256, seed=4)
    qs = jnp.asarray(query_workload(walks, 6, noise_sigma=0.05, seed=5))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=64,
                                             backend="pallas"))
    mesh = jax.make_mesh((8,), ("data",))
    ix.shard(mesh)
    for k in (1, 5, 10):
        d, i = ix.search(qs, k=k, sync_every=2)
        db, ib = search_bruteforce(jnp.asarray(walks), qs, k=k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                                   rtol=1e-5, atol=1e-5)
    print("sharded pallas knn OK")
    """)


def test_sharded_engine_bit_identical_zero_retrace():
    """Tentpole acceptance: sharded `submit().result()` is bit-identical
    to `FreshIndex.search` on the sharded index for k in {1, 5, 10} on
    BOTH kernel backends, with plan-cache counters proving zero
    re-traces after warmup, on a 2-device host mesh."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    mesh = jax.make_mesh((2,), ("data",))
    for backend, n, L in (("ref", 512, 128), ("pallas", 256, 64)):
        walks = random_walk(n, L, seed=11)
        qs = query_workload(walks, 8, noise_sigma=0.05, seed=12)
        ix = FreshIndex.build(walks, IndexConfig(
            leaf_capacity=32, backend=backend)).shard(mesh)
        with ix.engine(EngineConfig(max_batch=4, sync_every=2)) as eng:
            eng.warmup(ks=(1, 5, 10), buckets=(4,))
            warm = eng.stats()["plan_cache"]["misses"]
            for k in (1, 5, 10):
                for _ in range(2):
                    d, i = eng.submit(qs[:4], k=k).result(timeout=600)
                    df, if_ = ix.search(jnp.asarray(qs[:4]), k=k,
                                        sync_every=2)
                    np.testing.assert_array_equal(np.asarray(i),
                                                  np.asarray(if_))
                    np.testing.assert_array_equal(np.asarray(d),
                                                  np.asarray(df))
            st = eng.stats()["plan_cache"]
            assert st["misses"] == warm, (backend, st, warm)
            assert st["hits"] > 0
    print("sharded engine bit-identity + zero retrace OK")
    """, devices=2)


def test_sharded_engine_epochs_and_auto_compact():
    """Mesh-wide epoch snapshots under concurrent add(): the in-flight
    batch answers on its pre-add snapshot, the later submit sees the new
    series exactly (replicated-delta merge plan), and auto_compact_rows
    folds the delta through merge_sorted_delta + re-shard, republishing
    a delta-free epoch."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(512, 128, seed=13)
    qs = query_workload(walks, 8, noise_sigma=0.05, seed=14)
    extra = random_walk(32, 128, seed=15)
    mesh = jax.make_mesh((2,), ("data",))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    with ix.engine(EngineConfig(max_batch=8)) as eng:
        f_pre = eng.submit(qs[:4], k=5)          # in flight at epoch 0
        eng.add(extra)                           # publish epoch 1
        f_post = eng.submit(qs[:4], k=5)
        eng.flush()
        d_pre, i_pre = f_pre.result(timeout=600)
        d_post, i_post = f_post.result(timeout=600)
        db, ib = search_bruteforce(jnp.asarray(walks),
                                   jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(i_pre, np.asarray(ib))
        both = np.concatenate([walks, extra])
        db2, ib2 = search_bruteforce(jnp.asarray(both),
                                     jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(i_post, np.asarray(ib2))
        # the delta-carrying sharded engine path == the facade path
        df, if_ = ix.search(jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(i_post, np.asarray(if_))
        np.testing.assert_array_equal(d_post, np.asarray(df))
    ix2 = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    with ix2.engine(EngineConfig(max_batch=8,
                                 auto_compact_rows=16)) as eng:
        eng.add(extra)                           # 32 >= 16: auto-compact
        assert ix2.n_pending == 0 and ix2.mesh is not None
        assert eng.stats()["compactions"] == 1
        d, i = eng.submit(qs[:4], k=10).result(timeout=600)
        both = np.concatenate([walks, extra])
        db3, ib3 = search_bruteforce(jnp.asarray(both),
                                     jnp.asarray(qs[:4]), k=10)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib3))
    print("sharded epochs + auto-compact OK")
    """, devices=2)


def test_sharded_engine_crash_helping_and_elastic_recovery():
    """A shard batch whose worker crashes mid-dispatch is re-executed
    through the WorkJournal helping path (the future still fills,
    bit-identical); a PERMANENT loss is survived by recover(): restore
    the latest checkpoint arrays, re-shard over the surviving 1-device
    mesh, republish — without dropping the in-flight future."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile, threading
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.core.refresh import WorkerCrash
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(512, 128, seed=21)
    qs = query_workload(walks, 8, noise_sigma=0.05, seed=22)
    mesh = jax.make_mesh((2,), ("data",))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    eng = ix.engine(EngineConfig(max_batch=8, workers=1, linger_ms=1.0,
                                 help_after_ms=20.0))
    try:
        crashed = threading.Event()
        def hook(wid, batch):
            if wid >= 0 and not crashed.is_set():
                crashed.set()
                raise WorkerCrash()
        eng._crash_hook = hook
        fut = eng.submit(qs[:3], k=3)
        assert crashed.wait(60), "worker never acquired the batch"
        d, i = fut.result(timeout=600)       # caller helps via the journal
        df, if_ = ix.search(jnp.asarray(qs[:3]), k=3)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(if_))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(df))
        st = eng.stats()
        assert st["workers"]["crashed"] == 1
        assert st["batches"]["helped"] >= 1

        ckpt = tempfile.mkdtemp()
        ix.save(ckpt)
        f_old = eng.submit(qs[:4], k=5)      # pending at the old epoch
        m1 = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        eng.recover(ckpt, mesh=m1)           # permanent loss of device 1
        f_new = eng.submit(qs[:4], k=5)
        d_o, i_o = f_old.result(timeout=600)
        d_n, i_n = f_new.result(timeout=600)
        db, ib = search_bruteforce(jnp.asarray(walks),
                                   jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(np.asarray(i_o), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(i_n), np.asarray(ib))
        st = eng.stats()
        assert st["recoveries"] == 1
        assert st["mesh"] == {"axes": {"data": 1}, "devices": 1}
    finally:
        eng.close()
    print("sharded crash helping + elastic recovery OK")
    """, devices=2)


def test_sharded_search_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import build_index, run_search, build_sharded_search, \\
        shard_index
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(2048, 256, seed=1)
    qs = query_workload(walks, 12, noise_sigma=0.05, seed=2)
    raw = jnp.asarray(walks)
    idx = build_index(raw, leaf_capacity=64)
    d0, i0 = run_search(idx, jnp.asarray(qs))
    mesh = jax.make_mesh((8,), ("data",))
    sidx = shard_index(idx, mesh)
    fn = build_sharded_search(mesh, sync_every=2)
    d1, i1 = fn(sidx, jnp.asarray(qs))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-4, atol=1e-4)
    print("sharded search OK")
    """)


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-moe-a2.7b",
                                  "jamba-v0.1-52b", "mamba2-130m",
                                  "llama4-maverick-400b-a17b"])
def test_sharded_train_step_matches_unsharded(arch):
    """Same smoke model, same batch: (2 data x 4 model) mesh step must
    reproduce the single-device loss (MoE EP shard_map, seq-sharded
    attention, TP, the loss/embed shard_maps — all covered)."""
    _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import LM, param_values
    from repro.models.transformer import make_train_step
    from repro.optim import AdamW
    from repro.runtime.sharding import make_plan
    from repro.launch.specs import (abstract_params, param_shardings,
                                    batch_shardings, input_specs)

    cfg = smoke_config("{arch}")
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = param_values(model.init(key))
    opt = AdamW(lr=1e-3)
    st = opt.init(params)
    B, T = 8, 32
    kb = jax.random.PRNGKey(9)
    batch = {{"tokens": jax.random.randint(kb, (B, T), 0, cfg.vocab),
              "labels": jax.random.randint(kb, (B, T), 0, cfg.vocab)}}
    if cfg.prefix_embed:
        batch["prefix"] = 0.01 * jnp.ones((B, cfg.n_prefix, cfg.d_model))

    # single device oracle
    s0 = jax.jit(make_train_step(model, opt))
    p0, st0, m0 = s0(params, st, batch, jnp.int32(0))

    # sharded
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = make_plan(cfg, mesh)
    s1 = jax.jit(make_train_step(model, opt, plan))
    p1, st1, m1 = s1(params, st, batch, jnp.int32(0))

    l0, l1 = float(m0["loss"]), float(m1["loss"])
    assert abs(l0 - l1) / max(abs(l0), 1e-9) < 2e-3, (l0, l1)
    # updated params agree
    f0 = jax.tree.leaves(p0)[0]
    f1 = jax.tree.leaves(p1)[0]
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1),
                               rtol=5e-3, atol=5e-3)
    print("loss", l0, l1)
    """)


def test_sharded_decode_matches_unsharded():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import LM, param_values
    from repro.models.transformer import make_prefill_step, make_serve_step
    from repro.runtime.sharding import make_plan

    cfg = smoke_config("granite-8b")
    model = LM(cfg)
    params = param_values(model.init(jax.random.PRNGKey(0)))
    B, S = 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pre0 = jax.jit(make_prefill_step(model, cache_pad=2))
    srv0 = jax.jit(make_serve_step(model))
    _, st0 = pre0(params, toks[:, :-1])
    lg0, _ = srv0(params, st0, toks[:, -1])

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan_p = make_plan(cfg, mesh, prefill=True)
    plan_d = make_plan(cfg, mesh, decode=True)
    pre1 = jax.jit(make_prefill_step(model, plan_p, cache_pad=2))
    srv1 = jax.jit(make_serve_step(model, plan_d))
    _, st1 = pre1(params, toks[:, :-1])
    lg1, _ = srv1(params, st1, toks[:, -1])
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               rtol=2e-3, atol=2e-3)
    print("decode sharded OK")
    """)


def test_elastic_checkpoint_reshard():
    """Save params sharded on a (4,2) mesh, restore onto (2,4) — the
    pod-loss re-mesh path."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, load_checkpoint
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    m1 = jax.make_mesh((4, 2), ("data", "model"))
    t1 = jax.device_put(t, {"w": NamedSharding(m1, P("data", "model"))})
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, t1)
    m2 = jax.make_mesh((2, 4), ("data", "model"))
    sh2 = {"w": NamedSharding(m2, P("data", "model"))}
    restored, _ = load_checkpoint(d, t, shardings=sh2)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
    assert restored["w"].sharding.mesh.shape["model"] == 4
    print("elastic reshard OK")
    """)


def test_compressed_allreduce_error_feedback():
    """int8 gradient all-reduce with error feedback: quantization error is
    carried, not lost — over steps the mean reduced value converges."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import make_compressed_allreduce
    mesh = jax.make_mesh((8,), ("data",))
    ar = make_compressed_allreduce(("data",))

    g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    def step(g, r):
        return shard_map(lambda gg, rr: ar({"g": gg}, {"g": rr}),
                         mesh=mesh, in_specs=(P("data", None), P("data", None)),
                         out_specs=({"g": P("data", None)},
                                    {"g": P("data", None)}),
                         check_rep=False)(g, r)
    r = jnp.zeros_like(g_global)
    exact = jnp.sum(g_global, axis=0)
    acc_err = []
    out, r2 = step(g_global, r)
    q1 = np.asarray(out["g"][0])
    e1 = np.abs(q1 - np.asarray(exact)).max()
    # feed the SAME grads again with the carried residual: the error must
    # shrink (error feedback compensates)
    out2, r3 = step(g_global, r2["g"])
    q2 = np.asarray(out2["g"][0])
    # two-step average approximates exact better than one quantized shot
    avg = (q1 + q2) / 2
    e2 = np.abs(avg - np.asarray(exact)).max()
    assert e2 < e1 * 0.75, (e1, e2)
    print("error feedback OK", e1, e2)
    """)
