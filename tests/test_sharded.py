"""Multi-device semantics: every sharded path must agree with its
single-device oracle.  Runs in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps seeing 1 device (jax pins the count at first init).
"""

import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_facade_knn_matches_oracle():
    """FreshIndex.shard(mesh): exact top-k on the sharded path, including
    a delta buffer and a compact() that re-pads leaves to the device
    count."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex
    from repro.core import search_bruteforce
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(2048, 256, seed=1)
    qs = jnp.asarray(query_workload(walks, 12, noise_sigma=0.05, seed=2))
    ix = FreshIndex.build(walks, leaf_capacity=64)
    mesh = jax.make_mesh((8,), ("data",))
    ix.shard(mesh)
    for k in (1, 10):
        d, i = ix.search(qs, k=k, sync_every=2)
        db, ib = search_bruteforce(jnp.asarray(walks), qs, k=k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                                   rtol=1e-5, atol=1e-5)
    extra = random_walk(100, 256, seed=3)         # 2148 series: 34 leaves,
    ix.add(extra); ix.compact()                   # pad_leaves -> 40
    both = jnp.asarray(np.concatenate([walks, extra]))
    d, i = ix.search(qs, k=10)
    db, ib = search_bruteforce(both, qs, k=10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
    print("sharded facade knn OK")
    """)


def test_sharded_pallas_backend_matches_oracle():
    """backend='pallas' (resolved from IndexConfig) on the sharded path:
    each device's refine closure runs the fused kernel; results must be
    identical to the ref backend and the brute-force oracle."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(1024, 256, seed=4)
    qs = jnp.asarray(query_workload(walks, 6, noise_sigma=0.05, seed=5))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=64,
                                             backend="pallas"))
    mesh = jax.make_mesh((8,), ("data",))
    ix.shard(mesh)
    for k in (1, 5, 10):
        d, i = ix.search(qs, k=k, sync_every=2)
        db, ib = search_bruteforce(jnp.asarray(walks), qs, k=k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_allclose(np.asarray(d), np.asarray(db),
                                   rtol=1e-5, atol=1e-5)
    print("sharded pallas knn OK")
    """)


def test_sharded_engine_bit_identical_zero_retrace():
    """Tentpole acceptance: sharded `submit().result()` is bit-identical
    to `FreshIndex.search` on the sharded index for k in {1, 5, 10} on
    BOTH kernel backends, with plan-cache counters proving zero
    re-traces after warmup, on a 2-device host mesh."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    mesh = jax.make_mesh((2,), ("data",))
    for backend, n, L in (("ref", 512, 128), ("pallas", 256, 64)):
        walks = random_walk(n, L, seed=11)
        qs = query_workload(walks, 8, noise_sigma=0.05, seed=12)
        ix = FreshIndex.build(walks, IndexConfig(
            leaf_capacity=32, backend=backend)).shard(mesh)
        with ix.engine(EngineConfig(max_batch=4, sync_every=2)) as eng:
            eng.warmup(ks=(1, 5, 10), buckets=(4,))
            warm = eng.stats()["plan_cache"]["misses"]
            for k in (1, 5, 10):
                for _ in range(2):
                    d, i = eng.submit(qs[:4], k=k).result(timeout=600)
                    df, if_ = ix.search(jnp.asarray(qs[:4]), k=k,
                                        sync_every=2)
                    np.testing.assert_array_equal(np.asarray(i),
                                                  np.asarray(if_))
                    np.testing.assert_array_equal(np.asarray(d),
                                                  np.asarray(df))
            st = eng.stats()["plan_cache"]
            assert st["misses"] == warm, (backend, st, warm)
            assert st["hits"] > 0
    print("sharded engine bit-identity + zero retrace OK")
    """, devices=2)


def test_sharded_engine_epochs_and_auto_compact():
    """Mesh-wide epoch snapshots under concurrent add(): the in-flight
    batch answers on its pre-add snapshot, the later submit sees the new
    series exactly (replicated-delta merge plan), and auto_compact_rows
    folds the delta through merge_sorted_delta + re-shard, republishing
    a delta-free epoch."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(512, 128, seed=13)
    qs = query_workload(walks, 8, noise_sigma=0.05, seed=14)
    extra = random_walk(32, 128, seed=15)
    mesh = jax.make_mesh((2,), ("data",))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    with ix.engine(EngineConfig(max_batch=8)) as eng:
        f_pre = eng.submit(qs[:4], k=5)          # in flight at epoch 0
        eng.add(extra)                           # publish epoch 1
        f_post = eng.submit(qs[:4], k=5)
        eng.flush()
        d_pre, i_pre = f_pre.result(timeout=600)
        d_post, i_post = f_post.result(timeout=600)
        db, ib = search_bruteforce(jnp.asarray(walks),
                                   jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(i_pre, np.asarray(ib))
        both = np.concatenate([walks, extra])
        db2, ib2 = search_bruteforce(jnp.asarray(both),
                                     jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(i_post, np.asarray(ib2))
        # the delta-carrying sharded engine path == the facade path
        df, if_ = ix.search(jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(i_post, np.asarray(if_))
        np.testing.assert_array_equal(d_post, np.asarray(df))
    ix2 = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    with ix2.engine(EngineConfig(max_batch=8,
                                 auto_compact_rows=16)) as eng:
        eng.add(extra)                           # 32 >= 16: auto-compact
        assert ix2.n_pending == 0 and ix2.mesh is not None
        assert eng.stats()["compactions"] == 1
        d, i = eng.submit(qs[:4], k=10).result(timeout=600)
        both = np.concatenate([walks, extra])
        db3, ib3 = search_bruteforce(jnp.asarray(both),
                                     jnp.asarray(qs[:4]), k=10)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib3))
    print("sharded epochs + auto-compact OK")
    """, devices=2)


def test_sharded_delete_matches_tombstone_oracle():
    """Lifecycle on the sharded path: delete() masks rows inside the
    mesh-wide plan (deleted leaves' rows carry the sentinel norm, the
    replicated delta carries an alive mask) and compaction drops them
    while re-sharding — both states bit-equal to the tombstone-aware
    brute-force oracle through facade AND engine."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(512, 128, seed=41)
    extra = random_walk(32, 128, seed=42)
    qs = jnp.asarray(query_workload(np.concatenate([walks, extra]), 8,
                                    noise_sigma=0.05, seed=43))
    mesh = jax.make_mesh((2,), ("data",))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    ix.add(extra)
    dead = [7, 200, 511, 512, 530]            # core + delta ids
    assert ix.delete(dead) == len(dead)
    raw = jnp.asarray(np.concatenate([walks, extra]))
    alive = np.ones(544, bool); alive[dead] = False
    alive = jnp.asarray(alive)
    for k in (1, 5, 10):
        d, i = ix.search(qs, k=k)
        db, ib = search_bruteforce(raw, qs, k=k, alive=alive)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(db))
    ix.compact()                              # physical drop + re-shard
    assert ix.n_series == 544 - len(dead) and ix.n_deleted == 0
    with ix.engine(EngineConfig(max_batch=8)) as eng:
        d, i = eng.submit(qs, k=10).result(timeout=600)
        db, ib = search_bruteforce(raw, qs, k=10, alive=alive)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(db))
    print("sharded delete oracle OK")
    """, devices=2)


def test_sharded_engine_crash_helping_and_elastic_recovery():
    """A shard batch whose worker crashes mid-dispatch is re-executed
    through the WorkJournal helping path (the future still fills,
    bit-identical); a PERMANENT loss is survived by recover(): restore
    the latest checkpoint arrays, re-shard over the surviving 1-device
    mesh, republish — without dropping the in-flight future."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile, threading
    from repro.api import FreshIndex, IndexConfig
    from repro.core import search_bruteforce
    from repro.core.refresh import WorkerCrash
    from repro.serve import EngineConfig
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(512, 128, seed=21)
    qs = query_workload(walks, 8, noise_sigma=0.05, seed=22)
    mesh = jax.make_mesh((2,), ("data",))
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=32)).shard(mesh)
    eng = ix.engine(EngineConfig(max_batch=8, workers=1, linger_ms=1.0,
                                 help_after_ms=20.0))
    try:
        crashed = threading.Event()
        def hook(wid, batch):
            if wid >= 0 and not crashed.is_set():
                crashed.set()
                raise WorkerCrash()
        eng._crash_hook = hook
        fut = eng.submit(qs[:3], k=3)
        assert crashed.wait(60), "worker never acquired the batch"
        d, i = fut.result(timeout=600)       # caller helps via the journal
        df, if_ = ix.search(jnp.asarray(qs[:3]), k=3)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(if_))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(df))
        st = eng.stats()
        assert st["workers"]["crashed"] == 1
        assert st["batches"]["helped"] >= 1

        ckpt = tempfile.mkdtemp()
        ix.save(ckpt)
        f_old = eng.submit(qs[:4], k=5)      # pending at the old epoch
        m1 = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        eng.recover(ckpt, mesh=m1)           # permanent loss of device 1
        f_new = eng.submit(qs[:4], k=5)
        d_o, i_o = f_old.result(timeout=600)
        d_n, i_n = f_new.result(timeout=600)
        db, ib = search_bruteforce(jnp.asarray(walks),
                                   jnp.asarray(qs[:4]), k=5)
        np.testing.assert_array_equal(np.asarray(i_o), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(i_n), np.asarray(ib))
        st = eng.stats()
        assert st["recoveries"] == 1
        assert st["mesh"] == {"axes": {"data": 1}, "devices": 1}
    finally:
        eng.close()
    print("sharded crash helping + elastic recovery OK")
    """, devices=2)


def test_sharded_search_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import build_index, run_search, build_sharded_search, \\
        shard_index
    from repro.data.synthetic import random_walk, query_workload
    walks = random_walk(2048, 256, seed=1)
    qs = query_workload(walks, 12, noise_sigma=0.05, seed=2)
    raw = jnp.asarray(walks)
    idx = build_index(raw, leaf_capacity=64)
    d0, i0 = run_search(idx, jnp.asarray(qs))
    mesh = jax.make_mesh((8,), ("data",))
    sidx = shard_index(idx, mesh)
    fn = build_sharded_search(mesh, sync_every=2)
    d1, i1 = fn(sidx, jnp.asarray(qs))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-4, atol=1e-4)
    print("sharded search OK")
    """)


def test_elastic_checkpoint_reshard():
    """Save params sharded on a (4,2) mesh, restore onto (2,4) — the
    pod-loss re-mesh path."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, load_checkpoint
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    m1 = jax.make_mesh((4, 2), ("data", "model"))
    t1 = jax.device_put(t, {"w": NamedSharding(m1, P("data", "model"))})
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, t1)
    m2 = jax.make_mesh((2, 4), ("data", "model"))
    sh2 = {"w": NamedSharding(m2, P("data", "model"))}
    restored, _ = load_checkpoint(d, t, shardings=sh2)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
    assert restored["w"].sharding.mesh.shape["model"] == 4
    print("elastic reshard OK")
    """)
