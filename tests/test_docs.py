"""Documentation stays true or the build goes red.

Two enforcement layers for the docs/ overhaul (tier-1, no jax import):

* docs-freshness — every BENCH_fresh.json row name cited verbatim in
  EXPERIMENTS.md must exist in the committed BENCH_fresh.json, and
  docs/ARCHITECTURE.md + docs/SERVING.md must exist, be linked from the
  README, and reference real source files.  Perf claims that drift from
  the committed record fail here instead of silently rotting.
* pydocstyle-lite — an AST pass over the public surface (repro.api,
  repro.serve.engine, repro.core.builder): every public function/method
  carries a real docstring, and the lifecycle classes (FreshIndex,
  QueryEngine, IndexBuilder) additionally document every parameter by
  name and state a one-line `Concurrency:` contract on each non-property
  public method.
"""

import ast
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(rel: str) -> str:
    with open(os.path.join(ROOT, *rel.split("/"))) as f:
        return f.read()


# --------------------------------------------------------------------- #
# docs freshness
# --------------------------------------------------------------------- #
# a verbatim row citation: `fig3/...`, `fig5/...`, `serve/...`,
# `build/...`, `maint/...`, `quality/...`, `kernels/...` in backticks.
# Shorthand
# families (`build/pipeline/w{2,4}`, `fig3/query/*/ref`, `serve/...`)
# fall outside the character class or the filter below and are not
# checked — EXPERIMENTS.md must cite at least MIN_CITATIONS exact names
# so the check cannot go vacuous.
ROW_RE = re.compile(
    r"`((?:fig\d+|serve|build|maint|quality|kernels)/[A-Za-z0-9_/.-]+)`")
MIN_CITATIONS = 10


def _cited_rows(text: str):
    return [c for c in ROW_RE.findall(text)
            if ".." not in c and not c.endswith("/")]


def test_experiments_cites_only_committed_bench_rows():
    rows = {r["name"] for r in json.loads(_read("BENCH_fresh.json"))["rows"]}
    cited = _cited_rows(_read("EXPERIMENTS.md"))
    assert len(cited) >= MIN_CITATIONS, (
        f"EXPERIMENTS.md cites only {len(cited)} bench rows verbatim; "
        f"perf claims must reference committed BENCH_fresh.json row names")
    missing = sorted({c for c in cited if c not in rows})
    assert not missing, (
        f"EXPERIMENTS.md cites rows absent from the committed "
        f"BENCH_fresh.json: {missing}")
    quality = [c for c in cited if c.startswith("quality/")]
    assert quality, (
        "EXPERIMENTS.md §Approximate search must cite at least one "
        "committed `quality/...` bench row verbatim")
    kernels = [c for c in cited if c.startswith("kernels/")]
    assert kernels, (
        "EXPERIMENTS.md §Autotune must cite at least one committed "
        "`kernels/...` bench row verbatim")
    assert "kernels/refine/roofline_frac" in cited, (
        "EXPERIMENTS.md must cite the asserted roofline_frac row")


def test_docs_exist_and_linked_from_readme():
    for rel in ("docs/ARCHITECTURE.md", "docs/SERVING.md"):
        assert os.path.exists(os.path.join(ROOT, *rel.split("/"))), rel
    readme = _read("README.md")
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/SERVING.md" in readme
    arch = _read("docs/ARCHITECTURE.md")
    for mod in ("core/refresh.py", "core/traverse.py", "core/builder.py",
                "core/index.py", "core/search.py", "serve/engine.py",
                "runtime/elastic.py"):
        assert mod in arch, f"ARCHITECTURE.md lost its map entry for {mod}"
    serving = _read("docs/SERVING.md")
    for knob in ("max_batch", "linger_ms", "workers", "donate",
                 "auto_compact_rows", "sync_every", "help_after_ms",
                 "latency_tiers", "recall_target",
                 "round_leaves", "dma_depth", "block_q"):
        assert knob in serving, f"SERVING.md lost the {knob} knob"


def test_readme_migration_table_shows_no_deprecated_call_as_current():
    """The deprecated free functions may only appear in the 'old call'
    column / prose about deprecation — never as the recommended spelling
    (the stale-snippet bug this PR fixes)."""
    readme = _read("README.md")
    for line in readme.splitlines():
        if "|" not in line:
            continue
        cols = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cols) >= 2 and "make_sharded_search" in cols[-1]:
            raise AssertionError(
                f"deprecated make_sharded_search shown as the NEW call: "
                f"{line!r}")
        if len(cols) >= 2 and re.search(r"(?<![_.\w])search\(idx",
                                        cols[-1]):
            raise AssertionError(
                f"deprecated free search() shown as the NEW call: "
                f"{line!r}")


# --------------------------------------------------------------------- #
# pydocstyle-lite: the public surface documents itself
# --------------------------------------------------------------------- #
MODULES = {
    "src/repro/api.py": ("FreshIndex",),
    "src/repro/serve/engine.py": ("QueryEngine",),
    "src/repro/core/builder.py": ("IndexBuilder",),
}


def _is_property(node) -> bool:
    for d in node.decorator_list:
        if isinstance(d, ast.Name) and d.id == "property":
            return True
        if isinstance(d, ast.Attribute) and d.attr in ("setter", "getter"):
            return True
    return False


def _check_def(rel, cls, node, strict, problems):
    where = f"{rel}:{node.lineno} {(cls + '.') if cls else ''}{node.name}"
    doc = ast.get_docstring(node)
    if not doc or len(doc.strip()) < 20:
        problems.append(f"{where}: missing or trivial docstring")
        return
    if not strict:
        return
    if "Concurrency:" not in doc:
        problems.append(f"{where}: no 'Concurrency:' contract line")
    a = node.args
    params = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)
              if p.arg not in ("self", "cls")]
    for name in params:
        if not re.search(rf"\b{re.escape(name)}\b", doc):
            problems.append(f"{where}: parameter '{name}' undocumented")


def test_public_surface_docstrings():
    problems = []
    for rel, contract_classes in MODULES.items():
        tree = ast.parse(_read(rel))
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not node.name.startswith("_"):
                    _check_def(rel, None, node, False, problems)
            elif isinstance(node, ast.ClassDef) \
                    and not node.name.startswith("_"):
                if not ast.get_docstring(node):
                    problems.append(f"{rel}: class {node.name} undocumented")
                strict_cls = node.name in contract_classes
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)) \
                            and not sub.name.startswith("_"):
                        _check_def(rel, node.name, sub,
                                   strict_cls and not _is_property(sub),
                                   problems)
    assert not problems, "public-surface docstring contract violated:\n" \
        + "\n".join(problems)
