"""The fused Pallas refinement path (backend='pallas') vs the reference
materializing path (backend='ref'): identical exact k-NN results on the
local and facade paths, graceful padding behaviour, and the
allocation-freedom guarantee (no (Q, K*M, L) intermediate in the lowered
HLO — the acceptance criterion of the fused kernel)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FreshIndex, IndexConfig
from repro.core import (build_index, run_search, search_bruteforce,
                        search_plan)
from repro.data.synthetic import query_workload, random_walk


@pytest.fixture(scope="module")
def padded_built():
    # 1000 % 64 != 0: the index carries padded entries AND the PQ carries
    # padded (lb=BIG) leaves — the shapes the kernel must survive
    walks = random_walk(1000, 256, seed=21)
    return walks, build_index(jnp.asarray(walks), leaf_capacity=64)


@pytest.mark.parametrize("k", [1, 5, 10])
def test_pallas_matches_ref_and_bruteforce(padded_built, k):
    walks, idx = padded_built
    q = jnp.asarray(query_workload(walks, 6, noise_sigma=0.05, seed=22))
    dr, ir = run_search(idx, q, k=k, backend="ref")
    dp, ip = run_search(idx, q, k=k, backend="pallas")
    db, ib = search_bruteforce(jnp.asarray(walks), q, k=k)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ib))
    # winners' distances are recomputed in direct form from identical
    # entry buffers -> identical floats
    np.testing.assert_array_equal(np.asarray(dp), np.asarray(dr))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(db),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Q", [1, 3])
def test_pallas_odd_query_and_round_shapes(padded_built, Q):
    """Non-multiple-of-block Q and a round width that doesn't divide the
    leaf count (K=5 over 16 leaves) — every dynamic slice hits the padded
    PQ tail."""
    walks, idx = padded_built
    q = jnp.asarray(query_workload(walks, Q, noise_sigma=0.02, seed=23))
    dr, ir = run_search(idx, q, k=3, round_leaves=5, backend="ref")
    dp, ip = run_search(idx, q, k=3, round_leaves=5, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(dp), np.asarray(dr))


def test_pallas_all_pruned_rounds(padded_built):
    """Queries that ARE collection members: after round one the BSF is ~0
    and every remaining leaf fails lb < BSF — the all-pruned round body
    (pl.when skip) must still terminate with the exact answer."""
    walks, idx = padded_built
    q = jnp.asarray(walks[7:10])
    dp, ip = run_search(idx, q, k=1, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ip), np.asarray([7, 8, 9]))
    assert np.all(np.asarray(dp) < 1e-3)


def test_facade_resolves_backend_from_config(padded_built):
    walks, _ = padded_built
    q = jnp.asarray(query_workload(walks, 4, noise_sigma=0.05, seed=24))
    outs = {}
    for bk in ("ref", "pallas"):
        ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=64,
                                                 backend=bk))
        outs[bk] = ix.search(q, k=5)
    np.testing.assert_array_equal(np.asarray(outs["ref"][1]),
                                  np.asarray(outs["pallas"][1]))
    np.testing.assert_array_equal(np.asarray(outs["ref"][0]),
                                  np.asarray(outs["pallas"][0]))
    # per-call override beats the config default
    ix = FreshIndex.build(walks, IndexConfig(leaf_capacity=64))
    d_o, i_o = ix.search(q, k=5, backend="pallas")
    np.testing.assert_array_equal(np.asarray(i_o), np.asarray(outs["ref"][1]))


def test_config_round_knobs_thread_through():
    """round_leaves / pq_budget from IndexConfig steer the search; a
    starved pq_budget yields upper bounds (the documented approximate
    contract), never better-than-exact distances."""
    walks = random_walk(512, 128, seed=25)
    q = jnp.asarray(query_workload(walks, 4, noise_sigma=0.05, seed=26))
    exact = FreshIndex.build(walks, IndexConfig(leaf_capacity=32))
    d_ex, _ = exact.search(q)
    starved = FreshIndex.build(
        walks, IndexConfig(leaf_capacity=32, round_leaves=2, pq_budget=2))
    d_pq, _ = starved.search(q)
    assert np.all(np.asarray(d_pq) >= np.asarray(d_ex) - 1e-5)
    # an ample budget stays exact
    d_ok, _ = starved.search(q, pq_budget=512)
    np.testing.assert_allclose(np.asarray(d_ok), np.asarray(d_ex),
                               rtol=1e-6, atol=1e-6)


def test_pallas_path_never_materializes_the_gather():
    """Acceptance criterion: the lowered HLO of the pallas-backend search
    contains NO (Q, K*M, L) tensor; the ref backend (positive control)
    does.  Q=4, K=4, M=32, L=64 -> the gather shape is 4x128x64."""
    walks = random_walk(256, 64, seed=27)
    idx = build_index(jnp.asarray(walks), leaf_capacity=32)
    q = jnp.asarray(query_workload(walks, 4, noise_sigma=0.05, seed=28))

    def lowered(backend):
        # search_plan is the jitted pure plan (the deprecated `search`
        # shim is a host-side wrapper and no longer .lower()s)
        return search_plan.lower(idx, q, k=5, round_leaves=4,
                                 backend=backend).as_text()

    gather_shape = "tensor<4x128x64xf32>"
    assert gather_shape in lowered("ref")        # control: ref materializes
    assert gather_shape not in lowered("pallas")  # fused: never exists
