"""Host fat-leaf tree (paper Section V-B1): concurrent in-place leaf
inserts, announce-array split safety, expeditive/standard modes."""

import threading

import numpy as np
import pytest

from repro.core import isax
from repro.core.tree import FatLeafTree, cas_min


def _words(n, segments=16, bits=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << bits, size=(n, segments)).astype(np.uint8)


def test_sequential_inserts_all_retrievable():
    t = FatLeafTree(leaf_capacity=8, n_threads=2)
    ws = _words(200)
    for i, w in enumerate(ws):
        t.insert(0, w, i, mode="standard")
    items = t.items()
    assert sorted(pl for _, pl in items) == list(range(200))


def test_split_preserves_membership_and_regions():
    t = FatLeafTree(leaf_capacity=4, n_threads=1)
    ws = _words(64, seed=1)
    for i, w in enumerate(ws):
        t.insert(0, w, i)
    # every leaf member's word must match the leaf's fixed prefix bits
    for leaf in t.leaves():
        for e in leaf.data:
            if e is None:
                continue
            w, _ = e
            # reconstruct membership: for each segment, the first
            # (depths[s]-1) bits below root must route to this leaf —
            # weaker invariant checked via re-descent:
            box, found = t._descend(w)
            assert found is leaf or isinstance(found, type(leaf))


def test_concurrent_inserts_linearizable_membership():
    """8 threads x 100 inserts; all payloads must be present exactly once
    reachable (at-least-once in structure, dedup by payload)."""
    t = FatLeafTree(leaf_capacity=8, n_threads=8)
    ws = _words(800, seed=2)
    errs = []

    def worker(tid):
        try:
            for i in range(tid * 100, (tid + 1) * 100):
                t.insert(tid, ws[i], i, mode="standard")
        except Exception as e:   # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    got = sorted(set(pl for _, pl in t.items()))
    assert got == list(range(800))


def test_expeditive_mode_single_owner():
    """Expeditive mode skips announces; correct when single-owner."""
    t = FatLeafTree(leaf_capacity=8, n_threads=2)
    ws = _words(100, seed=3)
    for i, w in enumerate(ws):
        t.insert(0, w, i, mode="expeditive")
    assert sorted(pl for _, pl in t.items()) == list(range(100))


def test_helping_sets_leaf_flag():
    t = FatLeafTree(leaf_capacity=64, n_threads=2)
    w = _words(1, seed=4)[0]
    t.insert(1, w, 0, mode="helping")
    leaf = t.leaves()[0]
    assert leaf.help_flag


def test_inorder_and_counts():
    t = FatLeafTree(leaf_capacity=4, n_threads=1)
    ws = _words(40, seed=5)
    for i, w in enumerate(ws):
        t.insert(0, w, i)
    nodes = t.inorder_nodes()
    assert len(nodes) >= 1
    payloads = [pl for _, pl in t.items()]
    assert len(set(payloads)) == 40


def test_cas_min_bsf():
    box = [np.inf]
    assert cas_min(box, 5.0)
    assert not cas_min(box, 7.0)
    assert cas_min(box, 2.0)
    assert box[0] == 2.0


def test_cas_min_concurrent():
    box = [np.inf]
    vals = np.random.default_rng(0).uniform(0, 100, 400)

    def worker(chunk):
        for v in chunk:
            cas_min(box, float(v))

    threads = [threading.Thread(target=worker, args=(vals[i::4],))
               for i in range(4)]
    for t_ in threads:
        t_.start()
    for t_ in threads:
        t_.join()
    assert box[0] == vals.min()


def test_tree_hypothesis_no_duplicates_random():
    """Property: random words/capacities -> every payload reachable exactly
    once via descent-consistent paths (would have caught the _descend /
    _build_split depth off-by-one)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4, 8, 16]),
           st.integers(20, 150))
    def prop(seed, cap, n):
        rng = np.random.default_rng(seed)
        ws = rng.integers(0, 256, size=(n, 8)).astype(np.uint8)
        t = FatLeafTree(segments=8, leaf_capacity=cap, n_threads=1)
        for i, w in enumerate(ws):
            t.insert(0, w, i)
        payloads = sorted(pl for _, pl in t.items())
        assert payloads == list(range(n)), "duplicate or lost payload"
        # descent consistency: every stored word re-descends to its leaf
        for leaf in t.leaves():
            for e in leaf.data:
                if e is None:
                    continue
                _, found = t._descend(e[0])
                assert found is leaf

    prop()
